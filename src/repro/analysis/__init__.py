"""repro.analysis — spatterlint, the static hot-path auditor.

Walks the closed jaxpr / lowered HLO of every executable the
``ExecutorCache`` can build — enumerated from a suite x placement matrix
without running anything — and checks the invariants PRs 1–5 established
(no sort in the timed region, one pallas_call per bucket, no donation in
cached executables, placement-string/sharding agreement, ...), plus a
Python-``ast`` concurrency lint over the serving layer.  See DESIGN.md
§12 and the rule registry in ``rules.py``.

Three front-ends share one report schema (``report.py``, jax-free):

    spatter --lint SUITE [--mesh BxL]      # CLI, exits non-zero
    GET /lint                              # daemon: audits the live cache
    python -m repro.analysis ...           # CI: the full matrix

``cost.py`` (spattercost, DESIGN.md §15) rides the same three surfaces:
``spatter --cost SUITE [--mesh auto|BxL]``, ``GET /cost``, and
``python -m repro.analysis --cost`` — static byte accounting of every
executable, reconciled against the lowered StableHLO and converted to
predicted GB/s via the BENCH-calibrated roofline; it also powers
``mesh="auto"`` placement selection everywhere a mesh is accepted.

Exports resolve lazily (PEP 562) like ``repro.serve``: importing
``repro.analysis.report`` or ``.ast_lint`` alone stays jax-free (pinned
by a tests/test_lint.py subprocess drift guard).
"""
import importlib

_EXPORTS = {
    "Violation": ".report",
    "LintReport": ".report",
    "Rule": ".rules",
    "RULES": ".rules",
    "ExecUnit": ".rules",
    "PlanUnit": ".rules",
    "ServeUnit": ".rules",
    "rules_for": ".rules",
    "run_rules": ".lint",
    "unit_for": ".lint",
    "lint_plan": ".lint",
    "lint_suite_file": ".lint",
    "lint_cache": ".lint",
    "lint_serve": ".lint",
    "UnitCost": ".cost",
    "CostReport": ".cost",
    "Calibration": ".cost",
    "cost_plan": ".cost",
    "cost_suite_file": ".cost",
    "cost_cache": ".cost",
    "auto_placement": ".cost",
    "select_shape": ".cost",
    "shape_cost": ".cost",
}

__all__ = list(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    return getattr(importlib.import_module(mod, __name__), name)
