"""STREAM baseline (copy/scale/add/triad) — paper Table 3's reference
column, used by bench_app_patterns for the Table 4 Pearson correlation."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .harness import emit, time_fn

N = 1 << 22


def run(runs: int = 5) -> dict:
    a = jnp.asarray(np.random.default_rng(0).standard_normal(N), jnp.float32)
    b = jnp.asarray(np.random.default_rng(1).standard_normal(N), jnp.float32)
    scalar = jnp.float32(3.0)
    kernels = {
        "copy": (jax.jit(lambda a, b: a + 0), 2),
        "scale": (jax.jit(lambda a, b: scalar * a), 2),
        "add": (jax.jit(lambda a, b: a + b), 3),
        "triad": (jax.jit(lambda a, b: a + scalar * b), 3),
    }
    out = {}
    for name, (fn, streams) in kernels.items():
        t = time_fn(fn, a, b, runs=runs)
        gbs = streams * N * 4 / t / 1e9
        emit(f"stream/{name}", t * 1e6, f"{gbs:.2f}GB/s")
        out[name] = gbs
    return out


if __name__ == "__main__":
    run()
