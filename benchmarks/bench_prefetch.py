"""Paper Fig 4: prefetching on/off -> Pallas pipeline multi-buffering model.

The paper toggles HW prefetchers via MSRs; the TPU analogue is the Pallas
DMA pipeline's multiple-buffering (DESIGN.md §2).  We report the modeled
bandwidth with buffers=2 (prefetch ON: DMA overlaps compute) vs buffers=1
(prefetch OFF: every block pays full DMA latency), for the same strides as
Fig 4, plus the measured-CPU curve for methodology parity.
"""
from __future__ import annotations

from repro.core import make_pattern
from repro.core.bandwidth import pipeline_model
from .harness import emit

STRIDES = [1, 2, 4, 8, 16, 32, 64, 128]


def run(runs: int = 3):
    out = []
    for s in STRIDES:
        p = make_pattern(f"UNIFORM:16:{s}", kind="gather", delta=16 * s,
                         count=1 << 14, name=f"prefetch-s{s}")
        on = pipeline_model(p, 4, buffers=2)
        off = pipeline_model(p, 4, buffers=1)
        speedup = on["modeled_gbs"] / max(off["modeled_gbs"], 1e-12)
        # the paper's CPU prefetchers buy ~1.2-2x; the TPU pipeline gap is
        # latency-bound vs bandwidth-bound (a serial per-row DMA pays ~2us
        # each), so the modeled gap is orders of magnitude — this is WHY
        # scalar-granular gathers must never run unpipelined on TPU.
        emit(f"prefetch/s{s}", on["modeled_time_s"] * 1e6,
             f"pipelined={on['modeled_gbs']:.1f}GB/s "
             f"serial={off['modeled_gbs']:.3f}GB/s "
             f"(latency-bound; x{speedup:.0f})")
        out.append((s, on, off))
    return out


if __name__ == "__main__":
    run()
