"""Regression tests for GSEngine reuse after dst donation.

The scatter executable donates its dst (engine.build); caching the
donated buffer in ``self._built`` made the SECOND ``run()`` on any
scatter engine — and ``sharded()`` after ``run()`` — die with
"buffer has been deleted or donated".  Repeated execution on one engine
is the serving regime, so every backend pins it here.
"""
import jax
import numpy as np
import pytest

from repro.core import GSEngine, make_pattern
from repro.core import backends as B


def _scatter_pattern():
    # delta 2 < index span -> duplicate writes exercise the keep mask too
    return make_pattern("UNIFORM:4:2", kind="scatter", delta=2, count=16)


@pytest.mark.parametrize("backend", sorted(B.BACKENDS))
def test_scatter_run_twice(backend):
    eng = GSEngine(_scatter_pattern(), backend=backend)
    r1 = eng.run(runs=2)
    r2 = eng.run(runs=2)          # crashed before the fix
    assert r1.measured_gbs > 0 and r2.measured_gbs > 0


@pytest.mark.parametrize("backend", sorted(B.BACKENDS))
def test_scatter_sharded_after_run(backend):
    eng = GSEngine(_scatter_pattern(), backend=backend)
    eng.run(runs=1)
    mesh = jax.make_mesh((1,), ("data",))
    fn, args = eng.sharded(mesh)   # crashed before the fix (stale dst)
    out1 = np.asarray(fn(*args))
    # and the sharded executable itself is reusable: build() hands out a
    # fresh dst every call, so a second launch sees zeros again
    fn2, args2 = eng.sharded(mesh)
    out2 = np.asarray(fn2(*args2))
    np.testing.assert_array_equal(out1, out2)


@pytest.mark.parametrize("backend", sorted(B.BACKENDS))
def test_scatter_rerun_results_identical(backend):
    # donation must not leak state between calls: a rerun starts from a
    # fresh zero dst, so store-mode results are bit-identical
    eng = GSEngine(_scatter_pattern(), backend=backend)
    fn, args = eng.build()
    out1 = np.asarray(fn(*args))
    fn, args = eng.build()
    out2 = np.asarray(fn(*args))
    np.testing.assert_array_equal(out1, out2)


def test_gather_run_twice():
    eng = GSEngine(make_pattern("UNIFORM:4:1", kind="gather", delta=4,
                                count=16), backend="xla")
    r1 = eng.run(runs=2)
    r2 = eng.run(runs=2)
    assert r1.measured_gbs > 0 and r2.measured_gbs > 0


@pytest.mark.parametrize("backend", sorted(B.BACKENDS))
def test_engine_add_mode(backend):
    # mode= reaches the executable: duplicate writes accumulate in add
    # mode and last-write-win in store mode
    p = make_pattern("BROADCAST:4:2", kind="scatter", delta=0, count=4)
    store = GSEngine(p, backend=backend, mode="store")
    add = GSEngine(p, backend=backend, mode="add")
    fn_s, args_s = store.build()
    fn_a, args_a = add.build()
    out_s = np.asarray(fn_s(*args_s))
    out_a = np.asarray(fn_a(*args_a))
    assert not np.array_equal(out_s, out_a)
    # add twice through fresh dsts stays deterministic
    fn_a, args_a = add.build()
    np.testing.assert_allclose(np.asarray(fn_a(*args_a)), out_a,
                               rtol=1e-6, atol=1e-6)


def test_engine_rejects_unknown_mode():
    with pytest.raises(ValueError):
        GSEngine(_scatter_pattern(), mode="max")
