"""Pipeline parallelism vs sequential oracle (subprocess, 4 fake devices)."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys; sys.path.insert(0, %r)
    import jax, jax.numpy as jnp, numpy as np
    from repro.runtime.pipeline import pipeline_apply

    mesh = jax.make_mesh((4,), ("pipe",))
    L, B, D = 8, 8, 16
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.standard_normal((L, D, D)) * 0.2,
                               jnp.float32),
              "b": jnp.asarray(rng.standard_normal((L, D)) * 0.1,
                               jnp.float32)}
    x = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)

    def block(lp, x):
        return jnp.tanh(x @ lp["w"] + lp["b"])

    # sequential oracle
    def seq(params, x):
        def one(x, lp):
            return block(lp, x), None
        y, _ = jax.lax.scan(one, x, params)
        return y

    y_ref = seq(params, x)
    y_pipe = jax.jit(lambda p, x: pipeline_apply(
        mesh, block, p, x, n_micro=4))(params, x)
    assert np.allclose(y_pipe, y_ref, atol=1e-5), \
        float(jnp.abs(y_pipe - y_ref).max())

    # gradient: GPipe backward through ppermute transposition
    g_ref = jax.grad(lambda p: seq(p, x).sum())(params)
    g_pipe = jax.grad(lambda p: pipeline_apply(
        mesh, block, p, x, n_micro=4).sum())(params)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_pipe)):
        assert np.allclose(a, b, atol=1e-4), float(jnp.abs(a - b).max())

    # different microbatch counts agree
    y2 = jax.jit(lambda p, x: pipeline_apply(
        mesh, block, p, x, n_micro=8))(params, x)
    assert np.allclose(y2, y_ref, atol=1e-5)
    print("OK")
""") % REPO


def test_pipeline_matches_sequential():
    r = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True, timeout=420)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
