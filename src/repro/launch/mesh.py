"""Production mesh construction.

A FUNCTION (not module-level constant) so importing this module never
touches jax device state — required because the dry-run must set XLA_FLAGS
before the first jax device query.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (data, model) per pod; (2, 16, 16) (pod, data, model) across.

    The "pod" axis only carries data parallelism: cross-pod traffic is one
    gradient all-reduce per step (DESIGN.md §5).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Debug mesh over however many devices exist (tests, CPU examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))
