"""spatterd cold-vs-warm request latency (the serving layer's point).

Starts an in-process daemon on an ephemeral port with a fresh
ExecutorCache, POSTs the demo suite through a real HTTP round trip
twice, and reports:

    serve/cold_request   first request: compiles n_buckets executables
    serve/warm_request   identical repeat: compiles ZERO (asserted)
    serve/warm_speedup   cold/warm wall-clock ratio

The warm request is the product regime — "many scenarios per process
from millions of users" — where request latency is execute-only.  Bit
identity between the two responses is asserted via the per-pattern
output digests.
"""
from __future__ import annotations

import json
import time

from repro.core import ExecutorCache
from repro.serve import SpatterClient, SpatterDaemon

from .harness import emit

DEFAULT_SUITE = "suites/demo.json"


def run(runs: int = 3, suite: str = DEFAULT_SUITE, count_cap: int = 512):
    with open(suite) as f:
        pats = json.load(f)
    # cap pattern counts like bench_suite's --quick: the point here is
    # compile-vs-execute latency, not lane throughput
    for p in pats:
        p["count"] = min(int(p.get("count", 1)), count_cap)

    with SpatterDaemon(port=0, cache=ExecutorCache()) as d:
        client = SpatterClient(d.url)
        t0 = time.perf_counter()
        r1 = client.run_suite(pats, backend="xla", runs=runs)
        cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        r2 = client.run_suite(pats, backend="xla", runs=runs)
        warm = time.perf_counter() - t0

    assert r2["cache"]["misses"] == 0, r2["cache"]
    d1 = [row["digest"] for row in r1["stats"]["table"]]
    d2 = [row["digest"] for row in r2["stats"]["table"]]
    assert d1 == d2 and all(d1), "repeat request not bit-identical"

    emit("serve/cold_request", cold * 1e6,
         f"compiles={r1['cache']['misses']}")
    emit("serve/warm_request", warm * 1e6,
         f"compiles={r2['cache']['misses']}")
    emit("serve/warm_speedup", 0.0, f"{cold / warm:.1f}x")
