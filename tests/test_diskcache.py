"""core/diskcache — the crash-safe persistent executor tier (DESIGN.md §14).

The warm-restart contract, counter-proven: a fresh ExecutorCache on a
populated cache directory serves the whole suite with ``misses == 0``
(zero compiles) and bit-identical digests; corrupt or stale entries are
quarantined and recompiled, never loaded, never fatal; degraded
(fallback-built) executables are NOT persisted.
"""
import glob
import os

import jax
import pytest

from repro.core import DiskTier, ExecutorCache, SuitePlan, make_pattern
from repro.core.diskcache import QUAR_SUFFIX, SUFFIX, exec_key_str
from repro.core.plan import bucket_builder, enumerate_executables, run_plan

PLAN = SuitePlan.build([
    make_pattern("UNIFORM:8:1", kind="gather", delta=8, count=16),
    make_pattern("UNIFORM:8:2", kind="scatter", delta=2, count=16),
])
N_BUCKETS = PLAN.n_buckets


def _digests(cache):
    return [r.out_digest
            for r in run_plan(PLAN, runs=1, cache=cache, digest=True)]


def _entries(root):
    return sorted(glob.glob(os.path.join(root, "*" + SUFFIX)))


def _quarantined(root):
    return sorted(glob.glob(os.path.join(root, "*" + QUAR_SUFFIX)))


def test_round_trip_zero_compiles_bit_identical(tmp_path):
    root = str(tmp_path)
    cold = ExecutorCache(disk=DiskTier(root))
    ref = _digests(cold)
    assert cold.stats().misses == N_BUCKETS          # genuinely cold
    assert cold.disk.stats()["stores"] == N_BUCKETS  # all persisted
    assert len(_entries(root)) == N_BUCKETS

    # "restart": a brand-new process-level cache over the same directory
    warm = ExecutorCache()
    assert warm.attach_disk(DiskTier(root), preload=True) == N_BUCKETS
    assert _digests(warm) == ref                     # bit-identical
    s = warm.stats()
    assert s.misses == 0                             # ZERO compiles
    assert s.disk_hits == N_BUCKETS


def test_lazy_restore_without_preload(tmp_path):
    root = str(tmp_path)
    cold = ExecutorCache(disk=DiskTier(root))
    ref = _digests(cold)

    # no preload: each executable restores on first demand instead
    warm = ExecutorCache()
    assert warm.attach_disk(DiskTier(root), preload=False) == 0
    assert len(warm) == 0
    assert _digests(warm) == ref
    assert warm.stats().misses == 0
    assert warm.stats().disk_hits == N_BUCKETS
    assert warm.disk.stats()["loads"] == N_BUCKETS


def test_corrupt_entry_quarantined_and_recompiled(tmp_path):
    root = str(tmp_path)
    ref = _digests(ExecutorCache(disk=DiskTier(root)))
    victim = _entries(root)[0]
    raw = bytearray(open(victim, "rb").read())
    raw[-10] ^= 0xFF                                 # bit rot in payload
    with open(victim, "wb") as f:
        f.write(raw)

    warm = ExecutorCache()
    tier = DiskTier(root)
    assert warm.attach_disk(tier, preload=True) == N_BUCKETS - 1
    assert tier.stats()["quarantined"] == 1
    assert len(_quarantined(root)) == 1              # set aside, not deleted
    # serving still works: ONE recompile (the quarantined entry), and it
    # re-persists so the NEXT restart is fully warm again
    assert _digests(warm) == ref
    assert warm.stats().misses == 1
    assert len(_entries(root)) == N_BUCKETS
    warm2 = ExecutorCache()
    assert warm2.attach_disk(DiskTier(root), preload=True) == N_BUCKETS
    assert _digests(warm2) == ref
    assert warm2.stats().misses == 0


def test_stale_toolchain_entry_quarantined(tmp_path):
    root = str(tmp_path)
    _digests(ExecutorCache(disk=DiskTier(root)))
    victim = _entries(root)[0]
    raw = open(victim, "rb").read()
    head, _, payload = raw.partition(b"\n")          # MAGIC line
    header, _, payload = payload.partition(b"\n")
    header = header.replace(jax.__version__.encode(), b"0.0.0-stale", 1)
    with open(victim, "wb") as f:
        f.write(head + b"\n" + header + b"\n" + payload)

    tier = DiskTier(root)
    warm = ExecutorCache()
    assert warm.attach_disk(tier, preload=True) == N_BUCKETS - 1
    assert tier.stats()["quarantined"] == 1


def test_byte_budget_evicts_oldest(tmp_path):
    root = str(tmp_path)
    # a budget smaller than one entry: every store immediately evicts
    tier = DiskTier(root, budget_bytes=1)
    _digests(ExecutorCache(disk=tier))
    assert tier.stats()["stores"] == N_BUCKETS
    assert tier.stats()["evicted"] == N_BUCKETS
    assert _entries(root) == []


def test_degraded_fallback_flagged_and_not_persisted(tmp_path):
    tier = DiskTier(str(tmp_path))
    cache = ExecutorCache(disk=tier)
    key, builder, _ = enumerate_executables(PLAN)[0]

    def bad_builder():
        raise RuntimeError("injected: primary backend refused")

    fn, served, compiled, degraded = cache.serve_poly_info(
        key, bad_builder, fallback=builder)
    assert compiled and degraded
    assert fn is not None and served == key
    s = cache.stats()
    assert s.misses == 1 and s.degraded == 1
    # a degraded executable must NOT poison the persistent tier: the
    # healthy backend gets its chance again on the next restart
    assert tier.stats()["stores"] == 0 and _entries(str(tmp_path)) == []
    # warm hits on a degraded key stay flagged (every launch it serves
    # reports degraded, not just the first)
    _, _, compiled2, degraded2 = cache.serve_poly_info(key, builder)
    assert not compiled2 and degraded2


def test_restored_entries_are_marked_and_not_restored_again(tmp_path):
    root = str(tmp_path)
    _digests(ExecutorCache(disk=DiskTier(root)))
    warm = ExecutorCache()
    warm.attach_disk(DiskTier(root), preload=True)
    for _, fn in warm.entries():
        assert getattr(fn, "restored", False)
    # store() refuses a round-trip of a restored fn: it came FROM disk
    key, fn = warm.entries()[0]
    assert warm.disk.store(key, fn, None) is False
    assert warm.disk.stats()["store_failures"] == 0  # refusal, not failure


def test_key_str_covers_every_field():
    key, _, _ = enumerate_executables(PLAN)[0]
    s = exec_key_str(key)
    for field in ("backend", "kind", "idx_len", "batch", "dtype",
                  "placement"):
        assert f"{field}=" in s
