"""spatterd request latency: cold-vs-warm, and the scheduler's point —
multi-client concurrency (DESIGN.md §10/§13).

Part 1 (cold/warm, single client): starts an in-process daemon on an
ephemeral port with a fresh ExecutorCache, POSTs the demo suite through
a real HTTP round trip twice, and reports:

    serve/cold_request   first request: compiles n_buckets executables
    serve/warm_request   identical repeat: compiles ZERO (asserted)
    serve/warm_speedup   cold/warm wall-clock ratio

Part 2 (concurrency sweep): closed-loop clients — each thread posts its
suite, waits, posts again — at 1/4/16 clients, in two traffic shapes:

    shared     every client posts the SAME suite (the coalescing
               scheduler's best case: items stack into shared launches)
    disjoint   each client posts a different-geometry variant (distinct
               bucket families — no coalescing possible, pure queueing)

run twice per cell: ``workers=0`` (the PR 4 run-lock serialized
baseline) vs ``workers=2`` (the coalescing scheduler), warm in both
cases, reporting p50 per-request latency and the scheduler's launch /
coalesce counters.  The ISSUE 7 acceptance number is
``serve/speedup_p50_16shared``: scheduler p50 over the run-lock p50 in
the SAME process, same suite, same client count.

Part 3 (restart warmth, DESIGN.md §14): what the persistent disk tier
buys a restarted process.  Three first-request latencies on the same
suite:

    cold       fresh daemon, empty cache dir: full compile cost
    warm       same process, identical repeat: the in-process floor
    restart    a brand-NEW daemon on the now-populated cache dir —
               zero compiles (asserted), digests bit-identical to cold

merged as ``BENCH_suite.json: restart_warmth``; the headline ratio is
``cold / restart`` (how much of the ~20x cold penalty the disk tier
refunds across a process boundary).

The sweep merges into ``BENCH_suite.json`` (key ``serve_concurrency``)
so the serving-layer trajectory rides the canonical perf record, with
the same no-silent-clobber guard bench_sharded_suite uses
(``out_path=None`` on full CSV sweeps).
"""
from __future__ import annotations

import json
import os
import statistics
import tempfile
import threading
import time

from repro.core import ExecutorCache
from repro.serve import SpatterClient, SpatterDaemon

from .harness import emit

DEFAULT_SUITE = "suites/demo.json"
OUT_PATH = "BENCH_suite.json"
CLIENTS = (1, 4, 16)
ITERS = 3                # closed-loop requests per client per cell
N_VARIANTS = 3           # disjoint traffic cycles this many geometries


def _load_suite(suite: str, count_cap: int) -> list[dict]:
    with open(suite) as f:
        pats = json.load(f)
    # cap pattern counts like bench_suite's --quick: the point here is
    # serving latency, not lane throughput
    for p in pats:
        p["count"] = min(int(p.get("count", 1)), count_cap)
    return pats


def _variant(pats: list[dict], shift: int) -> list[dict]:
    """A geometry-distinct copy: halving ``count`` per shift moves every
    pattern into a different pow-2 bucket family, so disjoint traffic
    shares NO ExecKeys across variants (no coalescing possible)."""
    out = []
    for p in pats:
        q = dict(p)
        q["count"] = max(1, int(q["count"]) >> shift)
        out.append(q)
    return out


def _closed_loop(url: str, pats_for, n_clients: int, runs: int):
    """n closed-loop client threads, ITERS requests each; returns
    (p50_s, wall_s, n_requests)."""
    lats: list[float] = []
    lock = threading.Lock()
    errs: list[BaseException] = []

    def worker(i: int) -> None:
        c = SpatterClient(url)
        mine = []
        try:
            for _ in range(ITERS):
                t0 = time.perf_counter()
                r = c.run_suite(pats_for(i), backend="xla", runs=runs)
                mine.append(time.perf_counter() - t0)
                assert r["ok"]
        except BaseException as e:           # surfaced after join
            with lock:
                errs.append(e)
            return
        finally:
            c.close()
        with lock:
            lats.extend(mine)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errs:
        raise errs[0]
    return statistics.median(lats), wall, len(lats)


def _sweep_one(workers: int, pats: list[dict], runs: int) -> dict:
    """One daemon config, warm, across client counts x traffic shapes."""
    variants = [_variant(pats, s) for s in range(N_VARIANTS)]
    out: dict = {"shared": {}, "disjoint": {}}
    with SpatterDaemon(port=0, cache=ExecutorCache(),
                       workers=workers) as d:
        warm = SpatterClient(d.url)
        # compile everything the sweep can reach up front, so the timed
        # cells are execute-only.  A coalesced launch of j <= 16 requests
        # pads its combined member count to next_pow2(j*m), and every
        # such bracket equals next_pow2(m) * 2^i — so posting the suite
        # concatenated k-fold for k in {1,2,4,8,16} warms ALL brackets
        # the coalescing scheduler can mint (no k-folds needed for the
        # run-lock baseline, which never combines requests)
        folds = (1, 2, 4, 8, 16) if workers else (1,)
        for v in variants:
            for k in folds:
                warm.run_suite(v * k, backend="xla", runs=runs)
        warm.close()
        for n in CLIENTS:
            for shape, pats_for in (
                    ("shared", lambda i: variants[0]),
                    ("disjoint",
                     lambda i: variants[i % N_VARIANTS])):
                before = (d.scheduler.snapshot()
                          if d.scheduler is not None else None)
                p50, wall, n_req = _closed_loop(d.url, pats_for, n, runs)
                cell = {"p50_ms": p50 * 1e3, "wall_s": wall,
                        "requests": n_req}
                if before is not None:
                    after = d.scheduler.snapshot()
                    cell["launches"] = (after["total_launches"]
                                        - before["total_launches"])
                    cell["coalesced"] = (after["coalesced_launches"]
                                         - before["coalesced_launches"])
                out[shape][str(n)] = cell
    return out


def _restart_warmth(pats: list[dict], runs: int) -> dict:
    """Cold vs in-process-warm vs disk-warm-restart first-request
    latency (one process boundary crossed between cold and restart)."""
    cache_dir = tempfile.mkdtemp(prefix="bench-spatterd-")

    def timed(client):
        t0 = time.perf_counter()
        r = client.run_suite(pats, backend="xla", runs=runs)
        return time.perf_counter() - t0, r

    with SpatterDaemon(port=0, cache=ExecutorCache(),
                       cache_dir=cache_dir) as d:
        c = SpatterClient(d.url)
        cold_s, r_cold = timed(c)
        warm_s, r_warm = timed(c)
        assert r_warm["cache"]["misses"] == 0, r_warm["cache"]
        c.close()

    # the restart: a different PROCESS in spirit — fresh ExecutorCache,
    # fresh daemon, same cache directory.  run_request waits on the
    # readiness gate, so this latency honestly includes deserialization.
    with SpatterDaemon(port=0, cache=ExecutorCache(),
                       cache_dir=cache_dir) as d2:
        c = SpatterClient(d2.url)
        restart_s, r_restart = timed(c)
        assert r_restart["cache"]["misses"] == 0, r_restart["cache"]
        c.close()
    d_cold = [t["digest"] for t in r_cold["stats"]["table"]]
    d_restart = [t["digest"] for t in r_restart["stats"]["table"]]
    assert d_cold == d_restart and all(d_cold), (d_cold, d_restart)

    return {"cold_ms": cold_s * 1e3, "warm_ms": warm_s * 1e3,
            "restart_ms": restart_s * 1e3,
            "compiles_cold": r_cold["cache"]["misses"],
            "compiles_restart": 0,
            "restart_speedup": cold_s / restart_s,
            "warm_floor_ratio": restart_s / warm_s}


def run(runs: int = 3, suite: str = DEFAULT_SUITE, count_cap: int = 512,
        *, out_path: str | None = OUT_PATH):
    pats = _load_suite(suite, count_cap)

    # -- part 1: cold vs warm, single client ---------------------------------
    with SpatterDaemon(port=0, cache=ExecutorCache()) as d:
        client = SpatterClient(d.url)
        t0 = time.perf_counter()
        r1 = client.run_suite(pats, backend="xla", runs=runs)
        cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        r2 = client.run_suite(pats, backend="xla", runs=runs)
        warm = time.perf_counter() - t0

    assert r2["cache"]["misses"] == 0, r2["cache"]
    d1 = [row["digest"] for row in r1["stats"]["table"]]
    d2 = [row["digest"] for row in r2["stats"]["table"]]
    assert d1 == d2 and all(d1), "repeat request not bit-identical"

    emit("serve/cold_request", cold * 1e6,
         f"compiles={r1['cache']['misses']}")
    emit("serve/warm_request", warm * 1e6,
         f"compiles={r2['cache']['misses']}")
    emit("serve/warm_speedup", 0.0, f"{cold / warm:.1f}x")

    # -- part 2: multi-client sweep, run-lock baseline vs scheduler ----------
    sweep = {"suite": suite, "count_cap": count_cap, "runs": runs,
             "iters": ITERS, "clients": list(CLIENTS),
             "workers": {"0": _sweep_one(0, pats, runs),
                         "2": _sweep_one(2, pats, runs)}}
    for w, shapes in sweep["workers"].items():
        for shape, cells in shapes.items():
            for n, cell in cells.items():
                extra = (f";launches={cell['launches']}"
                         f";coalesced={cell['coalesced']}"
                         if "launches" in cell else "")
                emit(f"serve/p50_w{w}_{n}{shape}",
                     cell["p50_ms"] * 1e3,
                     f"wall={cell['wall_s']:.2f}s{extra}")
    # acceptance ratios: scheduler vs run-lock p50 at 16 clients.  On a
    # CPU host the shared-traffic cell is compute-bound (both paths do
    # the same total lane work, so parity is the physical expectation —
    # the coalescing win there is fewer launches and wall-clock, and the
    # latency win scales on real accelerators); disjoint traffic shows
    # the worker-overlap win directly.  Headline = geomean of the two.
    ratios = {}
    for shape in ("shared", "disjoint"):
        base = sweep["workers"]["0"][shape]["16"]["p50_ms"]
        sched = sweep["workers"]["2"][shape]["16"]["p50_ms"]
        ratios[shape] = base / sched
        emit(f"serve/speedup_p50_16{shape}", 0.0,
             f"{ratios[shape]:.2f}x")
    emit("serve/speedup_p50_16", 0.0,
         f"{(ratios['shared'] * ratios['disjoint']) ** 0.5:.2f}x")

    # -- part 3: restart warmth (disk tier across a process boundary) --------
    warmth = _restart_warmth(pats, runs)
    emit("serve/restart_cold", warmth["cold_ms"] * 1e3,
         f"compiles={warmth['compiles_cold']}")
    emit("serve/restart_warm", warmth["restart_ms"] * 1e3,
         "compiles=0 (disk)")
    emit("serve/restart_speedup", 0.0,
         f"{warmth['restart_speedup']:.1f}x")

    # -- merge into the canonical perf record --------------------------------
    if out_path:
        root = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                            ".."))
        if not os.path.isabs(out_path):
            out_path = os.path.join(root, out_path)
        doc = {}
        if os.path.exists(out_path):
            with open(out_path) as f:
                doc = json.load(f)
        doc["serve_concurrency"] = sweep
        doc["restart_warmth"] = warmth
        with open(out_path, "w") as f:
            json.dump(doc, f, indent=2)
        emit("serve/json", 0.0, out_path)
    return sweep


if __name__ == "__main__":
    run()
