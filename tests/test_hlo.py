"""core/hlo — the shared HLO/StableHLO shape+byte walker (DESIGN.md §15).

The walker is the single source of truth for "how many bytes does this
lowered signature move": launch/roofline.py (HLO-style ``f32[4,9]``
specs), core/tracing.py (``hlo_stats`` counters), and analysis/cost.py
(MLIR ``tensor<...>`` signatures) all import from it — pinned here by
identity asserts so the dedup cannot silently regress into copies.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp

from repro.core import hlo

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


# ---------------------------------------------------------------------------
# HLO-style specs (the roofline's input format)
# ---------------------------------------------------------------------------

def test_shape_bytes_hlo_specs():
    assert hlo.shape_bytes("f32[4,9]") == 4 * 9 * 4
    assert hlo.shape_bytes("s32[16]") == 64
    assert hlo.shape_bytes("pred[8]") == 8
    assert hlo.shape_bytes("bf16[2,3]") == 12
    assert hlo.shape_bytes("f32[]") == 4          # rank-0 scalar


def test_shape_dims():
    assert hlo.shape_dims("f32[4,9,1]") == [4, 9, 1]
    assert hlo.shape_dims("s32[]") == []


# ---------------------------------------------------------------------------
# MLIR tensor types (the StableHLO signature format)
# ---------------------------------------------------------------------------

def test_tensor_bytes_mlir():
    assert hlo.tensor_bytes("4x9x1xf32") == 4 * 9 * 1 * 4
    assert hlo.tensor_bytes("16xi32") == 64
    assert hlo.tensor_bytes("8xi1") == 8
    assert hlo.tensor_bytes("f32") == 4           # rank-0
    assert hlo.tensor_bytes("2x4xbf16") == 16


def test_tensor_bytes_unknown_or_dynamic_is_zero():
    # dynamic dims and exotic element types are unaccountable, not fatal
    assert hlo.tensor_bytes("?xf32") == 0
    assert hlo.tensor_bytes("4xcomplex-ish") == 0


# ---------------------------------------------------------------------------
# @main signature accounting against a REAL lowering
# ---------------------------------------------------------------------------

def test_main_io_bytes_matches_avals():
    def f(table, idx):
        return table[idx]

    table = jax.ShapeDtypeStruct((128,), jnp.float32)
    idx = jax.ShapeDtypeStruct((32,), jnp.int32)
    text = jax.jit(f).lower(table, idx).as_text()
    got = hlo.main_io_bytes(text)
    assert got["arg_bytes"] == 128 * 4 + 32 * 4
    assert got["result_bytes"] == 32 * 4
    assert got["total"] == got["arg_bytes"] + got["result_bytes"]


def test_main_signature_skips_bracket_soup_inside_quotes():
    # sharded modules annotate args with mhlo.sharding strings like
    # "{devices=[4,2]<=[8]}" — unbalanced brackets INSIDE quotes that a
    # naive depth counter trips over
    text = textwrap.dedent("""
        module @jit_f attributes {mhlo.num_partitions = 8 : i32} {
          func.func public @main(
              %arg0: tensor<4x8xf32> {mhlo.sharding = "{devices=[4,2]<=[8]}"},
              %arg1: tensor<16xi32>) -> (tensor<16xf32>
              {mhlo.sharding = "{replicated}"}) {
            %0 = stablehlo.constant dense<0> : tensor<16xf32>
            return %0 : tensor<16xf32>
          }
        }
    """)
    got = hlo.main_io_bytes(text)
    assert got["arg_bytes"] == 4 * 8 * 4 + 16 * 4
    assert got["result_bytes"] == 16 * 4


def test_hlo_stats_census():
    text = jax.jit(lambda x: jnp.sort(x)).lower(
        jax.ShapeDtypeStruct((64,), jnp.float32)).as_text()
    stats = hlo.hlo_stats(text)
    assert stats["num_partitions"] == 1
    assert stats["aliased_params"] == 0
    assert isinstance(stats["shardings"], set)


# ---------------------------------------------------------------------------
# dedup pins: every consumer resolves to THIS walker
# ---------------------------------------------------------------------------

def test_consumers_share_the_walker():
    from repro.core import tracing
    from repro.launch import roofline
    assert tracing.hlo_stats is hlo.hlo_stats
    assert roofline._shape_bytes is hlo.shape_bytes
    assert roofline._shape_dims is hlo.shape_dims
    assert roofline._DTYPE_BYTES is hlo.DTYPE_BYTES


def test_hlo_module_is_jax_free():
    # the module body is stdlib-only (it lives under the eager
    # repro.core package, so load it by path to test the file itself —
    # the same drift guard analysis/report.py and serve/client.py carry)
    path = os.path.join(SRC, "repro", "core", "hlo.py")
    code = (
        "import importlib.util, sys\n"
        f"spec = importlib.util.spec_from_file_location('hlo', {path!r})\n"
        "mod = importlib.util.module_from_spec(spec)\n"
        "spec.loader.exec_module(mod)\n"
        "assert mod.tensor_bytes('4xf32') == 16\n"
        "assert 'jax' not in sys.modules, 'hlo imported jax'\n")
    subprocess.run([sys.executable, "-c", code], check=True,
                   env=dict(os.environ))
