"""Suite execution planner: plan -> compile -> execute for pattern suites.

DESIGN NOTE (referenced from suite.py)
======================================

Problem.  ``run_suite`` used to build one ``GSEngine`` per pattern, so an
N-pattern suite paid N XLA compiles — compile time dwarfed execute time for
the paper's JSON suites (§3.3) and made streamed/repeated suite runs (the
"many scenarios per process" regime) unusable.

Plan.  ``SuitePlan.build`` groups patterns into **shape buckets**: the two
shape-bearing dims of a pattern's executable — the flattened index length
``count * index_len`` and the table ``footprint`` — are padded up to the
next power of two, and patterns whose ``(kind, padded_idx_len,
padded_footprint)`` agree share one bucket.  Pow-2 padding trades at most
2x wasted lanes for an O(log) number of distinct executable shapes.

Compile.  One executable per bucket shape: a ``jax.jit``-wrapped ``vmap``
of the single-pattern backend op (backends.gather_batched /
scatter_batched), with the pattern-batch as the mapped dim.  Executables
live in an ``ExecutorCache`` — an LRU keyed on ``(backend, kind, idx_len,
footprint, dtype, row_width, mode, batch, placement)`` — so repeated or
streamed suite runs reuse warm executables across ``run_suite`` calls.
The cache's ``misses`` counter is the compile counter: a 32-pattern suite
compiles ``n_buckets`` (< 32) executables, and a second identical run
compiles zero.

Batch polymorphism.  The pattern-batch dim itself is padded to the next
power of two (``pad_batch``), exactly like the lane dims: a bucket whose
member count drifts between streamed suite runs (31 patterns today, 29
tomorrow) keeps hitting the same padded batch, the same ``ExecKey``, and
the same traced executable — zero re-traces, where the unpadded batch dim
used to make jax silently re-trace on every membership change.  Lookup is
additionally batch-polymorphic across pow-2 brackets
(``ExecutorCache.best_batch``): a bucket whose membership *shrank* below
its old bracket reuses the smallest warm executable with a larger batch,
padding with more scratch patterns, so only genuine shape growth ever
compiles.  Because the padded batch is part of the ``ExecKey``,
``ExecutorCache.misses`` is an *exact* compile count: one cached
executable is only ever called with one input signature (each jitted
entry holds exactly one trace — asserted by tests).

Padded batch rows are scratch *patterns*: their index lanes all point at
the scratch table row, their tables/payloads are zeros, and their vmap
outputs are dropped before results are attributed — the same
can't-touch-real-data / never-in-the-numerator semantics as padded lanes.

Sharded launches.  ``run_plan(..., mesh=..., mesh_axis=...)`` splits every
bucket launch's pattern-batch dim over a mesh axis (the multi-device form
of the paper's §3.4 thread scaling): ``ShardedExecutor`` jits the same
batched op with ``NamedSharding``s from ``engine.gs_shardings(batched=
True)``, so each device runs the whole gather/scatter for its slice of
the bucket's patterns — a pattern never straddles devices, hence sharded
results are bit-identical to the single-device launch.  ``pad_batch``
additionally rounds the batch up to a multiple of the shard count so the
split is always even.  The mesh placement is part of the ``ExecKey``
(sharded and unsharded executables never collide).

Execute.  Same-bucket patterns are stacked: indices into a (B_pad, N_pad)
int32 array, tables into (B_pad, F_pad + 1, R).  Row ``F_pad`` of every
table is a scratch row; padded lanes (both the lane tail up to N_pad and,
for scatters, their payload) point at it, so they can never touch real
rows, and they never enter the bandwidth numerator — ``measured_gbs`` /
``modeled_gbs`` keep exactly the paper's §3.5 useful-bytes formula.
Per-pattern buffers come from ``engine.make_host_buffers`` — the same
function ``GSEngine`` uses — so batched results are bit-identical to
per-pattern execution (asserted by tests/test_suite_plan.py on all four
backends, and by tests/test_sharded_plan.py for the sharded path).

Hot-path hygiene.  Store-mode scatter needs last-write-wins dedup; its
keep mask is a pure function of the (static) padded index buffer, so
``_assemble_bucket`` computes it once on the host (backends.keep_last_mask)
and passes it to the executable as a fourth operand — no sort or dedup
primitive ever appears in a timed executable's jaxpr (asserted by
tests/test_no_sort.py).  On the pallas backend the batched ops are
batch-NATIVE kernels (a real grid over pattern-batch x tiles with the
index buffers scalar-prefetched once) rather than jax.vmap of per-pattern
pallas_calls, and store mode is one single-pass kernel launch per bucket.

Timing attribution.  A bucket launch is timed like GSEngine.run (min over
K runs, §3.5); each member pattern is attributed wall time proportional to
its share of the bucket's *launched* pattern lanes — scratch batch rows
count in the denominator (their share belongs to padding, not to any
member), so a member's reported bandwidth is invariant to how much batch
padding the serving executable carried, and every pattern in a bucket
reports the bandwidth the launch achieved.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import threading
import time
from collections import OrderedDict
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from . import backends as B
from . import bandwidth as bw
from .engine import (SCATTER_MODES, RunResult, gs_shardings,
                     make_host_buffers)
from .pattern import Pattern


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    if n < 1:
        raise ValueError(f"need n >= 1, got {n}")
    return 1 << (n - 1).bit_length()


def pad_batch(nb: int, n_shards: int = 1) -> int:
    """Padded pattern-batch dim: the smallest multiple of ``n_shards`` that
    is >= ``next_pow2(nb)`` (with ``n_shards=1`` that is exactly the next
    pow2).

    Pow-2 bracketing makes bucket executables batch-polymorphic in practice
    (member-count drift between suite runs lands on the same padded batch);
    the shard-count multiple keeps a sharded launch's batch split even.
    The shard round-up is applied ON TOP of the pow-2 bracket — never
    instead of it — so every member count in a bracket maps to ONE padded
    batch per shard count.  (The old behavior rounded ``ceil(nb/n_shards)``
    to a pow2 and could land *below* the bracket: nb=5, n_shards=3 gave 6
    while nb=7 gave 12, fragmenting the ``ExecKey.batch`` values that
    ``ExecutorCache.best_batch`` assumes are bracket-stable.)
    """
    if n_shards < 1:
        raise ValueError(f"need n_shards >= 1, got {n_shards}")
    b = next_pow2(nb)
    return math.ceil(b / n_shards) * n_shards


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """Shape signature shared by every pattern in a bucket."""
    kind: str           # "gather" | "scatter"
    idx_len: int        # count * index_len, padded to pow2
    footprint: int      # table footprint, padded to pow2

    @staticmethod
    def of(p: Pattern) -> "BucketSpec":
        return BucketSpec(kind=p.kind,
                          idx_len=next_pow2(p.count * p.index_len),
                          footprint=next_pow2(p.footprint()))


@dataclasses.dataclass(frozen=True)
class Bucket:
    spec: BucketSpec
    members: tuple[int, ...]      # positions into the suite's pattern list


@dataclasses.dataclass(frozen=True)
class SuitePlan:
    patterns: tuple[Pattern, ...]
    buckets: tuple[Bucket, ...]

    @staticmethod
    def build(patterns: Sequence[Pattern]) -> "SuitePlan":
        groups: dict[BucketSpec, list[int]] = {}
        for i, p in enumerate(patterns):
            groups.setdefault(BucketSpec.of(p), []).append(i)
        buckets = tuple(
            Bucket(spec=spec, members=tuple(groups[spec]))
            for spec in sorted(groups,
                               key=lambda s: (s.kind, s.idx_len, s.footprint)))
        return SuitePlan(patterns=tuple(patterns), buckets=buckets)

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    def pad_waste(self, n_shards: int = 1) -> float:
        """Fraction of launched lanes that are padding (0 = no waste).

        Counts both lane padding and the scratch patterns added by
        batch-dim padding (``pad_batch``, including the shard-multiple
        round-up when ``n_shards`` > 1).
        """
        real = sum(p.count * p.index_len for p in self.patterns)
        launched = sum(b.spec.idx_len * pad_batch(len(b.members), n_shards)
                       for b in self.buckets)
        return 1.0 - real / max(1, launched)


# ---------------------------------------------------------------------------
# Executor cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExecKey:
    backend: str
    kind: str
    idx_len: int
    footprint: int
    dtype: str
    row_width: int
    mode: str           # "store" | "add" for scatter, "" for gather
    batch: int          # padded pattern-batch dim (pad_batch)
    placement: str      # ShardedExecutor.placement, "" = single-device


@dataclasses.dataclass(frozen=True)
class CacheStats:
    """Point-in-time ``ExecutorCache`` counters (one consistent snapshot).

    ``misses`` is the exact compile count (see ExecutorCache).  The
    serving layer brackets each request with two snapshots and reports
    ``after.delta(before)`` — the request's own hits/misses — so a warm
    repeat request can *prove* it compiled nothing.
    """
    hits: int
    misses: int
    size: int

    def delta(self, before: "CacheStats") -> "CacheStats":
        """Elementwise difference — every field of the result is a delta
        (``size`` is net entry growth, which eviction can make negative);
        report absolute occupancy from the *after* snapshot instead."""
        return CacheStats(hits=self.hits - before.hits,
                          misses=self.misses - before.misses,
                          size=self.size - before.size)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class ExecutorCache:
    """LRU of compiled bucket executables; ``misses`` counts compiles.

    Keys carry the full input signature (bucket shape, padded batch, and
    mesh placement), so one entry is only ever invoked with one trace:
    ``misses`` equals the number of XLA compiles performed through the
    cache, exactly.

    Thread safety: all structure mutation (the LRU order, eviction, the
    hit/miss counters) happens under one internal lock, because the
    serving daemon's request handlers share the process-wide cache from
    multiple threads.  ``get`` holds the lock across ``builder()`` too —
    builders only wrap ``jax.jit`` (tracing/compilation is deferred to the
    first call), so the critical section stays cheap while guaranteeing a
    key is built at most once and ``misses`` never double-counts a race.
    """

    def __init__(self, maxsize: int = 128):
        self.maxsize = maxsize
        self._entries: OrderedDict[ExecKey, Callable] = OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def get(self, key: ExecKey, builder: Callable[[], Callable]) -> Callable:
        with self._lock:
            fn = self._entries.get(key)
            if fn is not None:
                self._entries.move_to_end(key)
                self.hits += 1
                return fn
            self.misses += 1
            fn = builder()
            self._entries[key] = fn
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)
            return fn

    def best_batch(self, key: ExecKey) -> ExecKey | None:
        """Smallest cached key differing from ``key`` only by a >= batch.

        The batch-polymorphic lookup: a warm executable compiled for a
        larger pattern-batch serves a smaller bucket by padding with more
        scratch patterns, so bucket-membership shrink never compiles.
        """
        with self._lock:
            best = None
            for k in self._entries:
                if (k.batch >= key.batch
                        and dataclasses.replace(k, batch=key.batch) == key
                        and (best is None or k.batch < best.batch)):
                    best = k
            return best

    def stats(self) -> CacheStats:
        """Consistent (hits, misses, size) snapshot."""
        with self._lock:
            return CacheStats(hits=self.hits, misses=self.misses,
                              size=len(self._entries))

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.hits = 0
            self.misses = 0


_DEFAULT_CACHE = ExecutorCache()


def default_cache() -> ExecutorCache:
    """Process-wide cache: repeated run_suite calls share warm executables."""
    return _DEFAULT_CACHE


def _raw_batched_fn(backend: str, kind: str, mode: str) -> Callable:
    """The (unjitted) bucket op — single source of truth for the bucket
    executable's signature, shared by the single-device and sharded
    builders so their operand lists can never drift apart."""
    if kind == "gather":
        def fn(src_b, idx_b):
            return B.gather_batched(src_b, idx_b, backend=backend)
    else:
        # keep is the host-precomputed last-write-wins mask over the padded
        # index buffer (unused in add mode); the traced body never sorts
        def fn(dst_b, idx_b, vals_b, keep_b):
            return B.scatter_batched(dst_b, idx_b, vals_b, mode=mode,
                                     backend=backend, keep=keep_b)
    return fn


def _build_executable(backend: str, kind: str, mode: str) -> Callable:
    return jax.jit(_raw_batched_fn(backend, kind, mode))


# ---------------------------------------------------------------------------
# Sharded executor
# ---------------------------------------------------------------------------

class ShardedExecutor:
    """Builds bucket executables whose pattern-batch dim is mesh-sharded.

    Wraps a ``(mesh, axis)`` pair.  ``build`` returns the same jitted
    batched op as the single-device path, but with in/out ``NamedSharding``s
    (``engine.gs_shardings(batched=True)``) splitting dim 0 — the
    pattern-batch — over ``axis``: each device executes the full
    gather/scatter for its slice of the bucket's patterns, so results are
    bit-identical to the unsharded launch.  ``placement`` feeds the
    ``ExecKey`` so sharded and unsharded executables never collide in the
    ``ExecutorCache``.
    """

    def __init__(self, mesh: Mesh, axis: str = "data"):
        if axis not in mesh.axis_names:
            raise ValueError(f"mesh has no axis {axis!r} "
                             f"(axes: {mesh.axis_names})")
        self.mesh = mesh
        self.axis = axis

    @property
    def n_shards(self) -> int:
        return self.mesh.shape[self.axis]

    @property
    def placement(self) -> str:
        return (f"{self.axis}={self.n_shards}"
                f"/{len(self.mesh.devices.flat)}dev")

    def shardings(self, kind: str):
        return gs_shardings(self.mesh, self.axis, kind, batched=True)

    def build(self, backend: str, kind: str, mode: str) -> Callable:
        in_sh, out_sh = self.shardings(kind)
        return jax.jit(_raw_batched_fn(backend, kind, mode),
                       in_shardings=in_sh, out_shardings=out_sh)

    def place(self, kind: str, args: tuple) -> tuple:
        """Commit assembled host buffers to their launch shardings.

        Keeps the device layout transfer out of the timed region (the jit
        would otherwise reshard uncommitted arrays inside every call).
        """
        in_sh, _ = self.shardings(kind)
        return tuple(jax.device_put(a, s) for a, s in zip(args, in_sh))


def _bucket_executable(cache: ExecutorCache, backend: str, spec: BucketSpec,
                       dtype, row_width: int, mode: str, n_members: int,
                       sharder: ShardedExecutor | None
                       ) -> tuple[Callable, int]:
    """Fetch (or compile) a bucket executable; returns (fn, batch).

    ``batch`` is the pattern-batch dim the executable was traced for —
    ``pad_batch`` of the member count, or the smallest warm executable's
    larger batch when one exists (``ExecutorCache.best_batch``); callers
    must assemble the bucket at exactly that batch.
    """
    key = ExecKey(backend=backend, kind=spec.kind, idx_len=spec.idx_len,
                  footprint=spec.footprint, dtype=jnp.dtype(dtype).name,
                  row_width=row_width,
                  mode=mode if spec.kind == "scatter" else "",
                  batch=pad_batch(n_members,
                                  sharder.n_shards if sharder else 1),
                  placement=sharder.placement if sharder else "")
    key = cache.best_batch(key) or key
    if sharder is not None:
        builder = lambda: sharder.build(backend, spec.kind, key.mode)
    else:
        builder = lambda: _build_executable(backend, spec.kind, key.mode)
    return cache.get(key, builder), key.batch


# ---------------------------------------------------------------------------
# Bucket assembly + execution
# ---------------------------------------------------------------------------

def _assemble_bucket(plan: SuitePlan, bucket: Bucket, dtype, row_width: int,
                     seed: int, batch: int | None = None,
                     mode: str = "store"):
    """Stack a bucket's patterns into batched device buffers.

    Returns (args, real_lanes) where args feeds the bucket executable and
    real_lanes[b] is member b's un-padded lane count.  Table row F_pad is
    the scratch row every padded lane points at.  ``batch`` (>= member
    count; default ``pad_batch``) sets the padded pattern-batch dim: rows
    past the member count are scratch patterns — all-scratch indices, zero
    tables/payloads — whose outputs the callers drop.

    Scatter buckets also carry the (B_pad, N_pad) last-write-wins keep
    mask for store mode: real lanes reuse the per-pattern mask
    ``make_host_buffers`` already computed (real indices never reach the
    scratch row F_pad, so padding can't change their dedup), and of the
    padding lanes — which ALL point at F_pad — only each row's final lane
    keeps, so the single-pass store kernel's at-most-one-write-per-row
    contract holds for every row including scratch.  In add mode (and in
    gather buckets) no mask is computed; the add executable's keep
    operand is an all-False placeholder it never reads.
    """
    spec = bucket.spec
    nb = len(bucket.members)
    b_pad = pad_batch(nb) if batch is None else batch
    if b_pad < nb:
        raise ValueError(f"batch {b_pad} < member count {nb}")
    n_pad, f_pad, r = spec.idx_len, spec.footprint, row_width
    idx_b = np.full((b_pad, n_pad), f_pad, np.int32)       # pad -> scratch
    table_b = (np.zeros((b_pad, f_pad + 1, r), np.float32)
               if spec.kind == "gather" else None)
    vals_b = (np.zeros((b_pad, n_pad, r), np.float32)
              if spec.kind == "scatter" else None)
    keep_b = (np.zeros((b_pad, n_pad), bool)
              if spec.kind == "scatter" else None)
    store = spec.kind == "scatter" and mode == "store"
    if store:
        keep_b[:, -1] = True       # scratch row's single write (pad lanes)
    real_lanes = []
    for b, pos in enumerate(bucket.members):
        p = plan.patterns[pos]
        src, abs_idx, vals, keep = make_host_buffers(p, r, seed=seed)
        n = abs_idx.shape[0]
        real_lanes.append(n)
        idx_b[b, :n] = abs_idx
        if spec.kind == "gather":
            table_b[b, :src.shape[0]] = src
        else:
            vals_b[b, :n] = vals
            if store:
                keep_b[b, :n] = keep      # n == n_pad overwrites the True
    idx = jnp.asarray(idx_b)
    if spec.kind == "gather":
        return (jnp.asarray(table_b, dtype), idx), real_lanes
    dst = jnp.zeros((b_pad, f_pad + 1, r), dtype)
    return (dst, idx, jnp.asarray(vals_b, dtype),
            jnp.asarray(keep_b)), real_lanes


def execute_bucket(plan: SuitePlan, bucket: Bucket, *, backend: str = "xla",
                   dtype=jnp.float32, row_width: int = 1,
                   mode: str = "store", seed: int = 0,
                   cache: ExecutorCache | None = None,
                   mesh: Mesh | None = None,
                   mesh_axis: str = "data") -> list[np.ndarray]:
    """Run one bucket once and return per-member un-padded outputs.

    Gathers give member i its (count*index_len, R) rows; scatters give the
    (footprint, R) result table (scratch row trimmed).  With ``mesh`` the
    launch's pattern-batch dim is split over ``mesh_axis``.
    """
    if mode not in SCATTER_MODES:
        raise ValueError(f"unknown mode {mode!r}; "
                         f"expected one of {SCATTER_MODES}")
    cache = cache if cache is not None else default_cache()
    sharder = ShardedExecutor(mesh, mesh_axis) if mesh is not None else None
    spec = bucket.spec
    fn, batch = _bucket_executable(cache, backend, spec, dtype, row_width,
                                   mode, len(bucket.members), sharder)
    args, real_lanes = _assemble_bucket(plan, bucket, dtype, row_width, seed,
                                        batch=batch, mode=mode)
    if sharder is not None:
        args = sharder.place(spec.kind, args)
    out = np.asarray(jax.block_until_ready(fn(*args)))
    trimmed = []
    for b, pos in enumerate(bucket.members):
        if spec.kind == "gather":
            trimmed.append(out[b, :real_lanes[b]])
        else:
            trimmed.append(out[b, :plan.patterns[pos].footprint()])
    return trimmed


def run_plan(plan: SuitePlan, *, backend: str = "xla", dtype=None,
             row_width: int = 1, runs: int = 10, mode: str = "store",
             seed: int = 0,
             cache: ExecutorCache | None = None,
             mesh: Mesh | None = None,
             mesh_axis: str = "data",
             digest: bool = False) -> list[RunResult]:
    """Execute a SuitePlan with paper-style timing (min over ``runs``).

    Returns one RunResult per pattern, in the suite's original order.
    Wall time of a bucket launch is attributed to members proportionally
    to their real (un-padded) lanes.

    With ``mesh``, every bucket launch's pattern-batch dim is split over
    ``mesh_axis`` (ShardedExecutor) — the multi-device suite regime.
    Reported bandwidth stays the paper's useful-bytes formula over the
    *aggregate* launch: divide by the shard count for per-device numbers.

    With ``digest``, each RunResult carries the sha256 of its trimmed
    computed output (``out_digest``).  The output is a pure function of
    (pattern, seed, mode, dtype) — batch padding and best_batch reuse
    never reach real rows — so equal digests across runs/processes mean
    bit-identical results; the serving layer uses this as its warm-repeat
    identity proof.
    """
    if backend not in B.BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    if mode not in SCATTER_MODES:
        raise ValueError(f"unknown mode {mode!r}; "
                         f"expected one of {SCATTER_MODES}")
    dtype = jnp.dtype(dtype or jnp.float32)
    cache = cache if cache is not None else default_cache()
    sharder = ShardedExecutor(mesh, mesh_axis) if mesh is not None else None
    elem_bytes = dtype.itemsize * row_width
    results: list[RunResult | None] = [None] * len(plan.patterns)

    for bucket in plan.buckets:
        spec = bucket.spec
        fn, batch = _bucket_executable(cache, backend, spec, dtype,
                                       row_width, mode, len(bucket.members),
                                       sharder)
        args, real_lanes = _assemble_bucket(plan, bucket, dtype, row_width,
                                            seed, batch=batch, mode=mode)
        if sharder is not None:
            args = sharder.place(spec.kind, args)
        if spec.kind == "scatter":
            dst, idx, vals, keep = args
            jax.block_until_ready(fn(dst, idx, vals, keep))  # compile & warm
            times = []
            for _ in range(runs):
                d = jnp.zeros_like(dst)
                if sharder is not None:
                    d = sharder.place(spec.kind, (d,))[0]
                jax.block_until_ready(d)
                t0 = time.perf_counter()
                out = fn(d, idx, vals, keep)
                jax.block_until_ready(out)
                times.append(time.perf_counter() - t0)
        else:
            jax.block_until_ready(fn(*args))                # compile & warm
            times = []
            for _ in range(runs):
                t0 = time.perf_counter()
                out = fn(*args)
                jax.block_until_ready(out)
                times.append(time.perf_counter() - t0)
        t_bucket = min(times)                                # paper §3.5
        out_np = np.asarray(out) if digest else None

        # attribution denominator counts scratch batch rows' lanes too, so
        # a member's reported bandwidth does not depend on how much batch
        # padding the serving executable carried (best_batch may hand a
        # small bucket a larger warm executable)
        total_lanes = (sum(real_lanes)
                       + (batch - len(bucket.members)) * spec.idx_len)
        for b, pos in enumerate(bucket.members):
            p = plan.patterns[pos]
            t_i = t_bucket * real_lanes[b] / total_lanes
            tm = bw.tpu_tile_model(p, elem_bytes)
            dg = None
            if digest:
                trim = (out_np[b, :real_lanes[b]] if spec.kind == "gather"
                        else out_np[b, :p.footprint()])
                dg = hashlib.sha256(
                    np.ascontiguousarray(trim).tobytes()).hexdigest()
            results[pos] = RunResult(
                pattern=p, backend=backend, elem_bytes=elem_bytes,
                row_width=row_width, runs=runs, time_s=t_i,
                measured_gbs=bw.paper_bandwidth(p, t_i, elem_bytes) / 1e9,
                modeled_gbs=tm.modeled_gbs,
                tile_efficiency=tm.tile_efficiency,
                out_digest=dg,
            )
    return results  # type: ignore[return-value]
